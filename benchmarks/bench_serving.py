"""Serving benchmark: token engine + design-service batching + chaos gates.

Three sections, all written to ``results/bench/serving.json``:

* **token** — the continuous-batching engine on a reduced qwen config:
  warm throughput, per-token latency and TTFT with mixed request sizes.
  Compile time is excluded by a warmup pass (the regression this bench
  once recorded — slots4 3x *slower* than slots1 — was per-prompt-length
  prefill retraces plus a full-cache copy per admit, both fixed; the gain
  is now HARD-GATED: >= 1.2 in ``--quick``, > 1.0 in the full run).

* **design** — the cross-request batching load generator: the same mixed
  simulate/explain query stream served sequentially
  (:class:`repro.serving.DesignService`) and through the coalescing
  :class:`repro.serving.BatchingDesignService`, reporting QPS and
  p50/p99 reply latency.  Both paths dispatch the same request-axis
  program at one pinned request bucket, so replies are bit-identical
  (asserted).  The QPS gain is HARD-GATED at > 1.5x.

* **design.pooled / design.multiprocess** — the serving-pool tier on the
  same mixed stream: the staged-dispatch :class:`PooledDesignService`
  (dispatcher thread + bounded worker pool, staging-buffer assembly) and
  the 2-worker :class:`MultiProcessDesignService` (worker processes over
  one shared preheated AOT cache).  Replies are bit-identical to the
  sequential baseline (asserted).  HARD-GATED: full run, pooled and
  multi-process QPS each > 1.5x the batched service; quick (the CI
  probe), availability == 1.0 and pooled QPS >= the batched QPS.  The
  full run also injects seeded **worker kills** (``p_worker_kill``) and
  gates kills >= 1, requeues >= 1, availability == 1.0 — a crashed
  worker's in-flight queries must be re-enqueued and answered exactly.

* **chaos** — the PR 7 resilience gates, now run against the BATCHED
  path (availability (fraction of queries answered ok within deadline),
  p50/p99 reply latency, retry and injection counts), with four hard
  gates —

    1. *isolation*: every batch completes, one reply per query, zero
       uncaught exceptions;
    2. *transient-only availability == 1.0*: every fault class that clears
       on retry MUST clear under the default policy (the CI probe's gate);
    3. *bit-identity*: replies for queries the chaos schedule left clean
       are bit-identical (``to_json`` string equality) to a no-chaos run,
       and the seeded schedule itself replays identically;
    4. *replay*: a fresh injector with the same seed reproduces schedule,
       outcomes and results exactly.

``--quick --chaos`` is the CI probe: design-service sections only, writing
``serving_quick.json`` (the canonical ``serving.json`` comes from a full
run on an idle machine).
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit, save_json
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import (
    BatchingDesignService,
    ChaosConfig,
    ChaosInjector,
    DesignQuery,
    DesignService,
    Engine,
    FlushPolicy,
    MultiProcessDesignService,
    PooledDesignService,
    Request,
    RetryPolicy,
)

_SEED = 20260808
_REQUEST_BUCKET = 16  # pinned request axis: sequential + batched share it


# --------------------------------------------------------------------------- #
# token engine
# --------------------------------------------------------------------------- #


def _token_requests(n: int, rng, vocab: int, max_tokens: int) -> list[Request]:
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, vocab, (int(rng.integers(4, 20)),)).astype(np.int32),
            max_tokens=max_tokens, temperature=0.0, seed=i,
        )
        for i in range(n)
    ]


def token_bench(quick: bool = False) -> dict:
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_req = 12 if quick else 16
    max_tokens = 32  # decode-heavy: the regime slot batching exists for
    out = {}
    for slots in (1, 4):
        eng = Engine(model, params, slots=slots, max_len=128)
        # warmup: one measurement-shaped pass (same prompt-length mix, same
        # max_tokens) compiles the prefill buckets, the admit write and the
        # decode step — measured numbers are the warm engine
        for r in _token_requests(n_req, np.random.default_rng(1), cfg.vocab_size, max_tokens):
            eng.submit(r)
        eng.run()
        eng.finished.clear()
        t0 = time.perf_counter()
        for r in _token_requests(n_req, np.random.default_rng(0), cfg.vocab_size, max_tokens):
            eng.submit(r)
        done = eng.run()
        wall = time.perf_counter() - t0
        toks = sum(len(r.generated) for r in done)
        ttft = float(np.mean([r.t_first - r.t_submit for r in done]))
        row = dict(slots=slots, requests=len(done), tok_per_s=round(toks / wall, 1),
                   mean_ttft_ms=round(ttft * 1e3, 1), wall_s=round(wall, 2))
        out[f"slots{slots}"] = row
        emit("serving", row)
    gain = out["slots4"]["tok_per_s"] / max(out["slots1"]["tok_per_s"], 1e-9)
    emit("serving", dict(batching_throughput_gain=round(gain, 2)))
    out["batching_gain"] = gain
    floor = 1.2 if quick else 1.0
    if gain < floor:
        raise SystemExit(
            f"GATE FAILED: token batching_gain {gain:.2f} < {floor} — slots must "
            "buy throughput, not lose it (prefill retraces / cache-copy regression?)"
        )
    return out


# --------------------------------------------------------------------------- #
# design-service cross-request batching load generator
# --------------------------------------------------------------------------- #


def _design_queries(n: int) -> list[DesignQuery]:
    """A deterministic mixed stream over one shape bucket — lstm, merge_sort,
    gcn and stencil2d all stack to (1, 32) — across four library
    architectures, so coalescing has real cross-request variety (different
    design points share one compiled program: parameters are traced data)."""
    kinds = ("simulate", "explain")
    loads = ("lstm", "merge_sort", "gcn", "stencil2d")
    archs = (None, "edge", "datacenter", "mobile")
    return [
        DesignQuery(i, kinds[i % 2], loads[(i // 2) % 4],
                    architecture=archs[(i // 8) % 4])
        for i in range(n)
    ]


def _fingerprints(replies) -> dict:
    """qid -> canonical result text for ok replies (bit-identity oracle:
    report objects serialize every float, so string equality is value
    equality down to the last bit)."""
    return {r.qid: r.result.to_json() for r in replies if r.ok}


def _lat_ms(replies) -> dict:
    walls = np.asarray([r.wall_s for r in replies if r.ok], np.float64)
    if not walls.size:
        return dict(p50_ms=None, p99_ms=None)
    return dict(p50_ms=round(float(np.percentile(walls, 50)) * 1e3, 2),
                p99_ms=round(float(np.percentile(walls, 99)) * 1e3, 2))


def design_bench(quick: bool = False) -> dict:
    n = 200 if quick else 1200
    queries = _design_queries(n)
    out: dict = {"queries": n, "request_bucket": _REQUEST_BUCKET}

    # sequential baseline: one query at a time, same pinned request bucket
    seq = DesignService("base", request_bucket=_REQUEST_BUCKET,
                        retry=RetryPolicy(max_attempts=4, base_s=0.005))
    t0 = time.perf_counter()
    seq_replies = seq.serve(queries)
    seq_wall = time.perf_counter() - t0
    out["sequential"] = dict(qps=round(n / seq_wall, 1), wall_s=round(seq_wall, 2),
                             ok=int(sum(r.ok for r in seq_replies)),
                             **_lat_ms(seq_replies))
    emit("serving.design", dict(mode="sequential", **out["sequential"]))

    # batched: load-generator arrival, size/age flush, coalesced dispatch
    policy = FlushPolicy(max_batch=_REQUEST_BUCKET, max_delay_s=0.005)
    bat = BatchingDesignService("base", policy=policy,
                                retry=RetryPolicy(max_attempts=4, base_s=0.005))
    bat_replies: list = []
    t0 = time.perf_counter()
    for q in queries:
        bat_replies.extend(bat.enqueue(q))
    bat_replies.extend(bat.flush())
    bat_wall = time.perf_counter() - t0
    st = bat.stats
    out["batched"] = dict(
        qps=round(n / bat_wall, 1), wall_s=round(bat_wall, 2),
        ok=int(sum(r.ok for r in bat_replies)),
        batches=st.batches, batched_queries=st.batched_queries,
        mean_batch=round(st.batched_queries / max(st.batches, 1), 2),
        **_lat_ms(bat_replies),
    )
    emit("serving.design", dict(mode="batched", **out["batched"]))

    assert len(seq_replies) == len(bat_replies) == n, "isolation: every query answers"
    fp_seq, fp_bat = _fingerprints(seq_replies), _fingerprints(bat_replies)
    mismatch = [q for q in fp_seq if fp_seq[q] != fp_bat.get(q)]
    out["bit_identical"] = not mismatch
    if mismatch:
        raise SystemExit(
            f"GATE FAILED: {len(mismatch)} batched replies differ from sequential "
            f"(qids {sorted(mismatch)[:8]}) — coalescing must not change answers"
        )

    gain = out["batched"]["qps"] / max(out["sequential"]["qps"], 1e-9)
    out["qps_gain"] = round(gain, 2)
    emit("serving.design", dict(qps_gain=out["qps_gain"]))
    if gain <= 1.5:
        raise SystemExit(
            f"GATE FAILED: batched design-query QPS gain {gain:.2f}x <= 1.5x — "
            "cross-request coalescing must buy real throughput"
        )

    out.update(_pool_bench(queries, fp_seq, out["batched"]["qps"], quick=quick))
    return out


def _pool_bench(queries, fp_seq: dict, batched_qps: float, *, quick: bool) -> dict:
    """The serving-pool tier on the same stream: threaded staged pool +
    2-worker multi-process fleet over a shared preheated AOT cache, each
    hard-gated on throughput, bit-identity and (mp) crash containment."""
    n = len(queries)
    policy = FlushPolicy(max_batch=_REQUEST_BUCKET, max_delay_s=0.005)
    retry = RetryPolicy(max_attempts=4, base_s=0.005)
    out: dict = {}

    # threaded pool: dispatcher thread + staged assembly, 2 workers
    with PooledDesignService("base", workers=2, policy=policy, retry=retry) as pool:
        t0 = time.perf_counter()
        replies = pool.serve(queries)
        wall = time.perf_counter() - t0
    out["pooled"] = dict(workers=2, qps=round(n / wall, 1), wall_s=round(wall, 2),
                         ok=int(sum(r.ok for r in replies)), **_lat_ms(replies))
    emit("serving.design", dict(mode="pooled", **out["pooled"]))
    _gate_identical(fp_seq, _fingerprints(replies), "pooled")

    # multi-process: the parent preheats ONE shared cache dir, workers
    # rehydrate from it (zero compiles) — QPS measured after ready
    cache_dir = tempfile.mkdtemp(prefix="dragon-bench-aot-")
    parent = BatchingDesignService("base", policy=policy, cache_dir=cache_dir)
    parent.warmup(["lstm", "merge_sort", "gcn", "stencil2d"])
    with MultiProcessDesignService("base", workers=2, cache_dir=cache_dir,
                                   policy=policy, retry=retry) as mp:
        t0 = time.perf_counter()
        replies = mp.serve(queries)
        wall = time.perf_counter() - t0
        traces = mp.stats.traces
    out["multiprocess"] = dict(workers=2, qps=round(n / wall, 1),
                               wall_s=round(wall, 2),
                               ok=int(sum(r.ok for r in replies)),
                               worker_traces=traces, **_lat_ms(replies))
    emit("serving.design", dict(mode="multiprocess", **out["multiprocess"]))
    _gate_identical(fp_seq, _fingerprints(replies), "multiprocess")

    for mode in ("pooled", "multiprocess"):
        row = out[mode]
        row["qps_vs_batched"] = round(row["qps"] / max(batched_qps, 1e-9), 2)
        if row["ok"] != n:
            raise SystemExit(
                f"GATE FAILED: {mode} availability {row['ok']}/{n} != 1.0"
            )
        floor = 1.0 if quick else 1.5
        hard = row["qps_vs_batched"] >= floor if quick else row["qps_vs_batched"] > floor
        if not hard:
            raise SystemExit(
                f"GATE FAILED: {mode} QPS {row['qps']} is {row['qps_vs_batched']}x "
                f"the batched service (floor {floor}x) — the pool must buy real "
                "throughput, not just concurrency"
            )
    emit("serving.design", dict(pooled_gain=out["pooled"]["qps_vs_batched"],
                                multiprocess_gain=out["multiprocess"]["qps_vs_batched"]))

    # seeded worker-kill chaos: a crashed worker's in-flight queries must be
    # requeued onto the survivor and answered bit-identically
    chaos = ChaosConfig(seed=_SEED, p_worker_kill=0.1)
    with MultiProcessDesignService("base", workers=2, cache_dir=cache_dir,
                                   policy=policy, retry=retry, chaos=chaos) as mpk:
        replies = mpk.serve(queries)
        info = mpk.pool_info
    out["worker_kill"] = dict(kills=info["kills"], requeues=info["requeues"],
                              ok=int(sum(r.ok for r in replies)),
                              alive=info["alive"])
    emit("serving.design", dict(mode="worker_kill", **out["worker_kill"]))
    if info["kills"] < 1 or info["requeues"] < 1:
        raise SystemExit(
            f"GATE FAILED: worker-kill chaos injected kills={info['kills']} "
            f"requeues={info['requeues']} — the crash fault must actually fire"
        )
    if out["worker_kill"]["ok"] != n:
        raise SystemExit(
            f"GATE FAILED: availability {out['worker_kill']['ok']}/{n} != 1.0 "
            "under worker-kill chaos — requeue must restore every in-flight query"
        )
    _gate_identical(fp_seq, _fingerprints(replies), "worker_kill")
    return out


def _gate_identical(fp_seq: dict, fp_got: dict, mode: str) -> None:
    mismatch = [q for q in fp_seq if fp_seq[q] != fp_got.get(q)]
    if mismatch:
        raise SystemExit(
            f"GATE FAILED: {len(mismatch)} {mode} replies differ from sequential "
            f"(qids {sorted(mismatch)[:8]}) — the pool must not change answers"
        )


# --------------------------------------------------------------------------- #
# design-service chaos probe (against the BATCHED path)
# --------------------------------------------------------------------------- #


def _queries(n: int, optimize_every: int = 0) -> list[DesignQuery]:
    """A deterministic mixed stream over one shape bucket (lstm/merge_sort
    share (1, 32)), so after the first cold queries everything is warm —
    the regime availability and p99 are defined on."""
    kinds = ("simulate", "explain")
    loads = ("lstm", "merge_sort")
    qs = []
    for i in range(n):
        if optimize_every and i and i % optimize_every == 0:
            qs.append(DesignQuery(i, "optimize", loads[i % 2],
                                  params=dict(steps=6, report=False)))
        else:
            qs.append(DesignQuery(i, kinds[i % 2], loads[(i // 2) % 2]))
    return qs


def _serve(queries, chaos=None, retry=None) -> tuple:
    """The chaos harness drives the BATCHED service: every gate below holds
    with coalescing on, which is the point — batching must not weaken any
    PR 7 guarantee."""
    svc = BatchingDesignService(
        "base", policy=FlushPolicy(max_batch=_REQUEST_BUCKET, max_delay_s=0.005),
        chaos=chaos, retry=retry or RetryPolicy(max_attempts=4, base_s=0.005))
    t0 = time.perf_counter()
    replies = svc.serve(queries)
    wall = time.perf_counter() - t0
    return svc, replies, wall


def _latency(replies, st) -> dict:
    return dict(
        queries=len(replies),
        ok=int(sum(r.ok for r in replies)),
        availability=round(st.availability, 6),
        retries=st.retries,
        deadline_misses=st.deadline_misses,
        degraded=st.degraded,
        errors=dict(st.errors),
        stragglers=len(st.stragglers),
        batches=st.batches,
        batched_queries=st.batched_queries,
        **_lat_ms(replies),
    )


def chaos_bench(quick: bool = False) -> dict:
    n = 24 if quick else 96
    queries = _queries(n, optimize_every=0 if quick else 24)
    out: dict = {"seed": _SEED, "queries": n}

    # 1) clean baseline: no chaos — also the bit-identity oracle
    svc0, replies0, wall0 = _serve(queries)
    base = _fingerprints(replies0)
    out["clean"] = {**_latency(replies0, svc0.stats), "wall_s": round(wall0, 2)}
    assert len(replies0) == len(queries), "isolation: batch must always complete"
    emit("serving.chaos", dict(mode="clean", **{k: out["clean"][k] for k in ("availability", "p50_ms", "p99_ms")}))

    # 2) transient-only chaos: every fault clears on retry -> the hard gate.
    # cache_corrupt (a torn persistent AOT entry, PR 9) is transient-class:
    # the reader quarantines + recompiles, so retry must clear it too.
    # Worst case transient+compile_fail+cache_corrupt costs 3 attempts, +1
    # clean = 4 == RetryPolicy.max_attempts, so availability stays 1.0.
    inj_t = ChaosInjector(ChaosConfig(seed=_SEED, p_transient=0.35, p_compile_fail=0.2,
                                      p_cache_corrupt=0.2))
    svc_t, replies_t, wall_t = _serve(queries, chaos=inj_t)
    out["transient_only"] = {**_latency(replies_t, svc_t.stats),
                             "injected": inj_t.summary(), "wall_s": round(wall_t, 2)}
    emit("serving.chaos", dict(mode="transient_only",
                               availability=out["transient_only"]["availability"],
                               injected=sum(inj_t.summary().values())))
    if out["transient_only"]["availability"] != 1.0:
        raise SystemExit(
            f"GATE FAILED: transient-only chaos availability "
            f"{out['transient_only']['availability']} != 1.0 — retryable faults "
            "must always clear under the default RetryPolicy"
        )

    # 3) full chaos: transients + NaN poisoning + latency spikes
    cfg = ChaosConfig(seed=_SEED, p_transient=0.3, p_compile_fail=0.1,
                      p_nan=0.25, p_latency=0.2, latency_s=0.02)
    inj_f = ChaosInjector(cfg)
    svc_f, replies_f, wall_f = _serve(queries, chaos=inj_f)
    stats_f = svc_f.stats
    plans = inj_f.schedule([q.qid for q in queries])
    clean_qids = {p.qid for p in plans if p.clean}
    fp_f = _fingerprints(replies_f)
    mismatch = [q for q in clean_qids if q in base and q in fp_f and base[q] != fp_f[q]]
    out["full"] = {
        **_latency(replies_f, stats_f),
        "injected": inj_f.summary(),
        "wall_s": round(wall_f, 2),
        "clean_queries": len(clean_qids),
        "bit_identical_clean": len(clean_qids) - len(mismatch),
        "schedule": [p.to_json() for p in plans if not p.clean],
    }
    emit("serving.chaos", dict(mode="full", availability=out["full"]["availability"],
                               p99_ms=out["full"]["p99_ms"],
                               injected=sum(inj_f.summary().values())))
    assert len(replies_f) == len(queries), "isolation: batch must always complete"
    if mismatch:
        raise SystemExit(
            f"GATE FAILED: {len(mismatch)} fault-free replies differ from the "
            f"no-chaos run (qids {sorted(mismatch)[:8]}) — chaos must not perturb "
            "untouched queries"
        )
    if out["full"]["availability"] < 0.99:
        raise SystemExit(
            f"GATE FAILED: full-chaos availability {out['full']['availability']} < 0.99"
        )

    # 4) determinism: same seed -> identical schedule and identical outcomes
    inj_r = ChaosInjector(cfg)
    svc_r, replies_r, _ = _serve(queries, chaos=inj_r)
    same_sched = [p.to_json() for p in inj_r.schedule([q.qid for q in queries])] == \
        [p.to_json() for p in inj_f.schedule([q.qid for q in queries])]
    same_outcome = [(r.qid, r.ok, r.error.code if r.error else None) for r in replies_r] == \
        [(r.qid, r.ok, r.error.code if r.error else None) for r in replies_f]
    same_results = _fingerprints(replies_r) == fp_f
    out["replay"] = dict(same_schedule=same_sched, same_outcomes=same_outcome,
                         same_results=same_results,
                         availability=round(svc_r.stats.availability, 6))
    if not (same_sched and same_outcome and same_results):
        raise SystemExit("GATE FAILED: seeded chaos replay diverged (schedule/outcomes/results)")
    emit("serving.chaos", dict(mode="replay", deterministic=True))
    return out


def run(quick: bool = False, chaos_only: bool = False) -> dict:
    out: dict = {}
    if not chaos_only:
        out.update(token_bench(quick))
    out["design"] = design_bench(quick)
    out["chaos"] = chaos_bench(quick)
    save_json("serving", out, quick=quick)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI probe sizes; writes serving_quick.json")
    ap.add_argument("--chaos", action="store_true",
                    help="design-service sections only (skip the token-engine bench)")
    args = ap.parse_args()
    run(quick=args.quick, chaos_only=args.chaos)
