"""§Roofline: façade-served roofline placements for the 11 DFG workloads.

For each (architecture x workload) the classic log-log placement:

  OI          = FLOPs / mainMem bytes moved      (operational intensity)
  ridge_oi    = peak_FLOP/s / DRAM_bw            (the machine's ridge point)
  attainable  = min(peak, OI * DRAM_bw)          (the roofline itself)
  achieved    = FLOPs / simulated runtime
  bottleneck  = memory if OI < ridge_oi else compute

Peaks come from the design point itself (``Architecture.peaks()`` — DGen's
specialized ConcreteHW at the timing-feasible clock), traffic and runtime
from one batched ``Session.simulate`` over all 11 workloads stacked into a
single shape bucket (one compile, one dispatch per architecture).

The payload is guaranteed non-empty: an empty roofline is a harness bug
(this bench once read a results directory that no longer existed and
silently wrote ``[]``), so ``run`` hard-fails rather than save it.
"""
from __future__ import annotations

from benchmarks.common import emit, save_json
from repro.api import Architecture, Session, Workload
from repro.workloads import WORKLOAD_FAMILIES

WORKLOADS = tuple(w for fam in WORKLOAD_FAMILIES.values() for w in fam)
ARCHS = ("base", "edge", "datacenter")


def run(quick: bool = False) -> dict:
    archs = ARCHS[:1] if quick else ARCHS
    w = Workload(list(WORKLOADS))
    rows = []
    for arch_name in archs:
        a = Architecture(arch_name)
        peaks = a.peaks()
        peak, bw = peaks["peak_flops"], peaks["mem_bw"]["mainMem"]
        ridge = peak / bw
        rep = Session(a).simulate(w)
        for g, wr in zip(w.graphs, rep.workloads):
            flops = float(g.total_flops)
            main = next(lv for lv in wr.levels if lv.level == "mainMem")
            dram_bytes = main.reads_bytes + main.writes_bytes
            oi = flops / max(dram_bytes, 1.0)
            attainable = min(peak, oi * bw)
            achieved = flops / max(wr.runtime_s, 1e-30)
            row = dict(
                arch=arch_name,
                workload=wr.label,
                flops=flops,
                dram_bytes=dram_bytes,
                oi=round(oi, 4),
                ridge_oi=round(ridge, 4),
                bottleneck="memory" if oi < ridge else "compute",
                t_compute=f"{flops / peak:.3e}",
                t_memory=f"{dram_bytes / bw:.3e}",
                runtime_s=f"{wr.runtime_s:.3e}",
                peak_flops=f"{peak:.3e}",
                attainable_flops=f"{attainable:.3e}",
                achieved_flops=f"{achieved:.3e}",
                utilization=round(achieved / max(attainable, 1e-30), 4),
            )
            rows.append(row)
            emit("roofline", row)
    if len(rows) != len(archs) * len(WORKLOADS):
        raise SystemExit(
            f"bench_roofline: expected {len(archs) * len(WORKLOADS)} placements, "
            f"got {len(rows)} — refusing to save a partial/empty roofline"
        )
    save_json("roofline", rows, quick=quick)
    return {"rows": rows}


if __name__ == "__main__":
    run()
