"""§Roofline: read the dry-run artifacts (results/dryrun/*.json) and emit the
three-term roofline table per (arch x shape x mesh):

  t_compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
  t_memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
  t_collective = link_bytes_per_device / link_bw            (~50 GB/s ICI)

plus MODEL_FLOPS = 6*N(_active)*D (2*N*D for inference) and the useful-
compute ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy waste),
and the dominant-term bottleneck tag."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import RESULTS_DIR, emit, save_json
from repro.configs import SHAPES, get_config
from repro.launch.dryrun import HBM_BW, LINK_BW, PEAK_FLOPS


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / chips
    return 2.0 * n * shape.global_batch / chips  # decode: one token/slot


def run(dryrun_dir: str | None = None, quick: bool = False) -> dict:
    d = dryrun_dir
    if d is None:  # prefer the corrected baseline sweep
        for cand in ("dryrun_base", "dryrun"):
            p = os.path.join(RESULTS_DIR, cand)
            if os.path.isdir(p) and glob.glob(os.path.join(p, "*.json")):
                d = p
                break
        else:
            d = os.path.join(RESULTS_DIR, "dryrun")
    rows = []
    for fn in sorted(glob.glob(os.path.join(d, "*.json"))):
        r = json.load(open(fn))
        if r.get("skipped") or not r.get("ok") or "roofline" not in r:
            continue
        if "flops_per_device" not in r:
            continue
        tc = r["flops_per_device"] / PEAK_FLOPS
        tm = r["bytes_per_device"] / HBM_BW
        tl = r.get("collectives", {}).get("total_bytes", 0) / LINK_BW
        bound = max((tc, "compute"), (tm, "memory"), (tl, "collective"))[1]
        mf = model_flops_per_device(r["arch"], r["shape"], r["chips"])
        step = max(tc, tm, tl)
        row = dict(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
            t_compute=f"{tc:.3e}", t_memory=f"{tm:.3e}", t_collective=f"{tl:.3e}",
            bottleneck=bound,
            useful_ratio=round(mf / max(r["flops_per_device"], 1.0), 3),
            mfu_bound=round(mf / PEAK_FLOPS / max(step, 1e-12), 4),
            hbm_gb=r.get("hbm_per_device_gb"),
        )
        rows.append(row)
        emit("roofline", row)
    save_json("roofline", rows, quick=quick)
    return {"rows": rows}


if __name__ == "__main__":
    run()
