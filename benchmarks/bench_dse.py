"""Paper §8.2 / Table 4 / Fig. 7: design-space exploration — DOpt derives an
optimized accelerator architecture per workload by gradient descent, with
the convergence curve recorded (single-pass, seconds — vs sweep hours)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import ArchParams, TechParams, optimize, simulate
from repro.workloads import get_workload, lm_cell

WORKLOADS = {
    "resnet50": lambda: get_workload("resnet50"),
    "bert_base": lambda: get_workload("bert_base"),
    "dlrm": lambda: get_workload("dlrm"),
    "qwen2.5-32b:train": lambda: lm_cell("qwen2.5-32b", "train_4k"),
    "falcon-mamba:decode": lambda: lm_cell("falcon-mamba-7b", "decode_32k"),
}


def run(quick: bool = False) -> dict:
    out = {}
    steps = 20 if quick else 60
    items = list(WORKLOADS.items())[:3] if quick else list(WORKLOADS.items())
    for name, make in items:
        g = make()
        t0 = time.perf_counter()
        res = optimize(g, objective="edp", opt_over="arch", steps=steps, lr=0.1)
        wall = time.perf_counter() - t0
        a = res.arch
        derived = dict(
            sys_arr=f"{float(a.sys_arr_x):.0f}x{float(a.sys_arr_y):.0f}x{float(a.sys_arr_n):.0f}",
            vect=f"{float(a.vect_width):.0f}x{float(a.vect_n):.0f}",
            gbuf_mb=round(float(a.capacity[1]) / 2**20, 1),
            freq_ghz=round(float(a.frequency) / 1e9, 2),
        )
        gain = res.history["edp"][0] / max(res.history["edp"][-1], 1e-300)
        row = dict(workload=name, edp_gain=round(gain, 1), wall_s=round(wall, 1),
                   epochs=len(res.history["edp"]), **derived)
        out[name] = dict(row=row, curve=res.history["edp"][:: max(1, steps // 20)])
        emit("dse", row)
    save_json("dse", out)
    return out


if __name__ == "__main__":
    run()
