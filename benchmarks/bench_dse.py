"""Paper §8.2 / Table 4 / Fig. 7: design-space exploration — DOpt derives an
optimized accelerator architecture per workload by gradient descent, with
the convergence curve recorded (single-pass, seconds — vs sweep hours).

Runs through the Session façade (the dopt engine underneath is unchanged);
starting points are named text architectures from the `.dhd` library
(``--arch``, default ``base`` — identical to the old dataclass defaults),
and a library sweep optimizes the same workload from several described
designs to show DSE launching straight from ``.dhd`` files."""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json
from repro.api import Architecture, MapperCfg, Session, Workload

WORKLOADS = {
    "resnet50": lambda: Workload("resnet50"),
    "bert_base": lambda: Workload("bert_base"),
    "dlrm": lambda: Workload("dlrm"),
    "qwen2.5-32b:train": lambda: _lm("qwen2.5-32b", "train_4k"),
    "falcon-mamba:decode": lambda: _lm("falcon-mamba-7b", "decode_32k"),
}


def _lm(arch: str, shape: str) -> Workload:
    from repro.workloads import lm_cell

    return Workload(lm_cell(arch, shape), labels=(f"{arch}:{shape}",))


def dopt_throughput(quick: bool = False) -> dict:
    """DOpt epochs/sec, before vs after the device-resident loop.

    before = per-step jitted dispatch with a host sync each epoch and the
    sequential O(V) ``lax.scan`` mapper (``fused=False, scan_impl="ref"``),
    retraced per call — a *conservative* stand-in for the pre-fusion driver,
    which additionally clamped bounds out-of-jit and made five scalar
    device->host transfers per epoch (so the true "before" was slower than
    measured here).  after = chunked-scan fused epochs + associative-scan
    mapper (the defaults).  Walls are reported cold (includes compile) and warm
    (compiled program cached across optimize() calls — the fleet steady
    state the fused path enables and the per-call-closure baseline cannot).
    Both run ``report=False``: only the descent is on the clock.
    """
    steps = 40 if quick else 200
    names = ["lstm", "bert_base", "merge_sort"]
    wl = Workload(names)
    sess = Session("base")

    def measure(label, **kw):
        t0 = time.perf_counter()
        sess.optimize(wl, objective="edp", steps=steps, lr=0.05, report=False, **kw)
        cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        sess.optimize(wl, objective="edp", steps=steps, lr=0.05, report=False, **kw)
        warm = time.perf_counter() - t0
        row = dict(variant=label, steps=steps, workloads=wl.n_workloads,
                   wall_cold_s=round(cold, 3), wall_warm_s=round(warm, 3),
                   epochs_per_s_warm=round(steps / warm, 1))
        emit("dopt_throughput", row)
        return row

    before = measure("per_step_loop", fused=False, mcfg=MapperCfg(scan_impl="ref"))
    after = measure("fused_device_resident", fused=True)
    summary = dict(
        workloads=names, steps=steps, before=before, after=after,
        speedup_warm=round(before["wall_warm_s"] / after["wall_warm_s"], 1),
        speedup_cold=round(before["wall_cold_s"] / after["wall_cold_s"], 2),
    )
    emit("dopt_throughput", dict(summary="1", speedup_warm=summary["speedup_warm"]))
    save_json("dopt_throughput", summary, quick=quick)
    return summary


def _describe(a: Architecture) -> dict:
    p = a.arch
    return dict(
        sys_arr=f"{float(p.sys_arr_x):.0f}x{float(p.sys_arr_y):.0f}x{float(p.sys_arr_n):.0f}",
        vect=f"{float(p.vect_width):.0f}x{float(p.vect_n):.0f}",
        gbuf_mb=round(float(p.capacity[1]) / 2**20, 1),
        freq_ghz=round(float(p.frequency) / 1e9, 2),
    )


def run(quick: bool = False, start_arch: str = "base") -> dict:
    sess = Session(Architecture(start_arch))  # named .dhd text architecture
    out = {"dopt_throughput": dopt_throughput(quick), "start_arch": start_arch}
    steps = 20 if quick else 60
    items = list(WORKLOADS.items())[:3] if quick else list(WORKLOADS.items())
    for name, make in items:
        wl = make()
        t0 = time.perf_counter()
        res = sess.optimize(wl, objective="edp", opt_over="arch", steps=steps,
                            lr=0.1, report=False)
        wall = time.perf_counter() - t0
        row = dict(workload=name, edp_gain=round(res.improvement, 1), wall_s=round(wall, 1),
                   epochs=res.epochs, **_describe(Architecture(res.to_dhd())))
        curve = list(res.objective_history[:: max(1, steps // 20)])
        out[name] = dict(row=row, curve=curve)
        emit("dse", row)

    # DSE launched from several *described* designs: same workload, library
    # starting points — how much each hand-written architecture leaves on
    # the table relative to its own optimum
    out["library_starts"] = {}
    wl = Workload("bert_base")
    for lib_name in ["edge", "datacenter"] if quick else ["edge", "mobile", "datacenter", "hbm_class"]:
        res = sess.optimize(wl, objective="edp", opt_over="arch", steps=steps, lr=0.1,
                            architecture=Architecture(lib_name), report=False)
        row = dict(start=lib_name, workload="bert_base", edp_gain=round(res.improvement, 1),
                   **_describe(Architecture(res.to_dhd())))
        out["library_starts"][lib_name] = row
        emit("dse", row)
    save_json("dse", out, quick=quick)
    return out


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="base", help="named .dhd library starting point")
    args = ap.parse_args()
    run(quick=args.quick, start_arch=args.arch)
