"""Paper §8.3 / Table 3 / Fig. 3: technology-target derivation.

(a) Table 3 — ranked technology-parameter importance per workload family
    (vision / language / recommendation), for both execution-time and
    energy objectives, from accumulated gradient elasticities.
(b) Fig. 3 — technology targets for a 100x EDP improvement of a BERT-class
    encoder, derived in ONE gradient pass (seconds), with the achieved
    factor and the ranked order in which parameters must improve.

All through the Session façade (tech_targets/optimize route to dopt).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, save_json
from repro.api import Session, Workload
from repro.workloads import WORKLOAD_FAMILIES


def run(quick: bool = False) -> dict:
    sess = Session("base")
    out = {"table3": {}, "targets_100x": None}
    steps = 10 if quick else 25
    for family, names in WORKLOAD_FAMILIES.items():
        if family == "non_ai":
            continue
        wl = Workload(list(names[:1] if quick else names))
        for objective in ("time", "energy"):
            res = sess.optimize(wl, opt_over="tech", objective=objective,
                                steps=steps, lr=0.05, report=False)
            top = [a.parameter.removeprefix("tech.") for a in res.importance[:5]]
            out["table3"][f"{family}/{objective}"] = top
            emit("tech_targets", dict(family=family, objective=objective,
                                      order=" > ".join(top[:4])))

    # 100x EDP derivation for BERT (paper Fig. 3)
    t0 = time.perf_counter()
    tt = sess.tech_targets(Workload("bert_base"), goal_factor=100.0,
                           objective="edp", steps=80 if quick else 400, lr=0.12)
    wall = time.perf_counter() - t0
    moved = sorted(tt["targets"].items(), key=lambda kv: -abs(kv[1]["factor"] - 1))
    top_moves = {k: round(v["factor"], 2) for k, v in moved[:6]}
    out["targets_100x"] = dict(achieved=round(tt["achieved_factor"], 1),
                               epochs=tt["epochs"], wall_s=round(wall, 1),
                               top_targets=top_moves,
                               importance=[n for n, _ in tt["importance"][:8]])
    emit("tech_targets", dict(goal="100x_edp_bert", achieved=out["targets_100x"]["achieved"],
                              epochs=tt["epochs"], wall_s=round(wall, 1)))
    emit("tech_targets", dict(top_targets=str(top_moves)))
    if tt["achieved_factor"] < 100.0:
        # pure-technology improvement saturates at the library's physical
        # bounds (~86x); the paper's 100x needs the architecture co-designed
        # (its framework does both) — report the joint path too
        res = sess.optimize(Workload("bert_base"), opt_over="both", objective="edp",
                            steps=30 if quick else 80, lr=0.1, target_factor=100.0,
                            report=False)
        out["targets_100x"]["joint_arch_tech_achieved"] = round(res.improvement, 1)
        emit("tech_targets", dict(goal="100x_edp_bert_joint",
                                  achieved=round(res.improvement, 1),
                                  epochs=res.epochs))
    save_json("tech_targets", out, quick=quick)
    return out


if __name__ == "__main__":
    run()
