"""Façade cache probe: the serving guarantee, measured and gated.

``Session`` keys compiled programs by (spec, mapper config, workload shape
bucket, objective signature); the serving pattern — repeated queries over
same-bucket workloads — must replay cached executables.  This bench records
to ``results/bench/api_cache.json`` (``--quick`` -> ``api_cache_quick.json``):

  * **cold** — first ``simulate()`` on a fresh Session (traces + compiles);
  * **warm** — repeated ``simulate()`` over same-bucket workloads (the
    original, a different workload, a different design point), each timed;
  * **optimize warm-over-mixes** — two ``optimize(objective="mixed")``
    calls with different weights/budgets: the second must add zero DOpt-step
    traces (weights are traced arguments, per PR 4);
  * **cold restart** — a subprocess preheats ``Session(cache_dir=...)``
    (AOT compile + serialized executables), a *second* subprocess constructs
    over the same cache_dir and serves its first simulate/explain: the wall
    from construction to first reply is ``cold_restart_s``, the persistent-
    cache payoff the ROADMAP item 2 work is gated on.

Acceptance gates (hard-fail, both modes):
  * zero new traces across the whole warm phase;
  * warm mean wall >= MIN_SPEEDUP x lower than cold;
  * restart: zero traces in the restarted process, replies bit-identical to
    the preheating (fresh-compile) process AND to this process's own cold
    reply, and ``cold_restart_s`` <= MAX_RESTART_FRACTION x ``cold_s``.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.api import Architecture, Session, Workload
from repro.core import instrument

MIN_SPEEDUP = 10.0
# a restarted worker must answer at least 4x faster than a cold compile
# (ISSUE 9 gate is 0.25 x cold_s; measured ~0.2s vs ~1.3s, ~6x headroom)
MAX_RESTART_FRACTION = 0.25
# one 32-vertex shape bucket, four distinct workloads
BUCKET_FAMILY = ["lstm", "merge_sort", "dlrm", "gcn"]

# Child 1: preheat the working set into the cache dir.  Its own replies are
# the fresh-compile reference — preheat AOT-compiled the programs in this
# very process, so serving through them IS a freshly-compiled session.
_PREHEAT_CHILD = r"""
import json, sys, time
from repro.api import Session
t0 = time.perf_counter()
sess = Session("base", cache_dir=sys.argv[1])
info = sess.preheat(["lstm"], objectives=("edp",), kinds=("simulate", "explain"))
preheat_s = time.perf_counter() - t0
sim = sess.simulate("lstm").to_json()
expl = sess.explain("lstm", objective="edp").to_json()
print(json.dumps(dict(preheat_s=preheat_s, built=info["built"],
                      persisted=info["persisted"], sim=sim, expl=expl)))
"""

# Child 2: the restarted worker.  cold_restart_s covers Session construction
# (deserializing every cache entry) + the first simulate AND explain — the
# window a fleet worker is unavailable after a restart.  The workload is
# prebuilt off the clock to match the parent's cold_s measurement (wls are
# constructed before the cold timer there); interpreter/jax import time is
# likewise excluded on both sides of the comparison.
_RESTART_CHILD = r"""
import json, sys, time
from repro.api import Session, Workload
from repro.core import instrument
w = Workload("lstm")
_ = w.stacked  # host-side stacking is cache-independent prep; off the clock
t0 = time.perf_counter()
sess = Session("base", cache_dir=sys.argv[1])
rep = sess.simulate(w)
expl = sess.explain(w, objective="edp")
cold_restart_s = time.perf_counter() - t0
print(json.dumps(dict(cold_restart_s=cold_restart_s, traces=sess.stats.traces,
                      global_traces=instrument.trace_count(),
                      disk_loaded=sess.disk_loaded,
                      sim=rep.to_json(), expl=expl.to_json())))
"""


def _child(code: str, cache_dir: str) -> dict:
    env = dict(os.environ)
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", code, cache_dir],
        capture_output=True, text=True, env=env, timeout=600,
    )
    if out.returncode != 0:
        raise SystemExit(f"bench_api restart child failed:\n{out.stderr}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def restart_bench(cold_s: float, cold_sim_json: str) -> tuple[dict, list]:
    """The subprocess preheat -> restart measurement + its gate failures."""
    cache_dir = tempfile.mkdtemp(prefix="dragon-aot-")
    try:
        pre = _child(_PREHEAT_CHILD, cache_dir)
        post = _child(_RESTART_CHILD, cache_dir)
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)
    checks = []
    if post["traces"] != 0 or post["global_traces"] != 0:
        checks.append(
            f"restarted process traced {post['traces']} session / "
            f"{post['global_traces']} global programs (must be 0)"
        )
    identical = post["sim"] == pre["sim"] and post["expl"] == pre["expl"]
    if not identical:
        checks.append("restarted replies not bit-identical to the preheating process")
    if post["sim"] != cold_sim_json:
        checks.append("restarted simulate differs from this process's fresh compile")
    budget = MAX_RESTART_FRACTION * cold_s
    if post["cold_restart_s"] > budget:
        checks.append(
            f"cold_restart_s {post['cold_restart_s']:.3f}s > "
            f"{MAX_RESTART_FRACTION} x cold_s = {budget:.3f}s"
        )
    section = dict(
        preheat_s=round(pre["preheat_s"], 3),
        preheat_built=pre["built"],
        preheat_persisted=pre["persisted"],
        cold_restart_s=round(post["cold_restart_s"], 4),
        restart_traces=post["traces"],
        restart_disk_loaded=post["disk_loaded"],
        restart_speedup_vs_cold=round(cold_s / max(post["cold_restart_s"], 1e-9), 1),
        restart_bit_identical=identical,
    )
    return section, checks


def run(quick: bool = False) -> dict:
    sess = Session("base")
    wls = {n: Workload(n) for n in BUCKET_FAMILY}
    assert len({w.bucket for w in wls.values()}) == 1, "probe family must share a bucket"

    # --- cold: first query compiles ---------------------------------------
    cold_rep, cold_s = timed(sess.simulate, wls["lstm"])
    cold_traces = sess.stats.traces

    # --- warm: same bucket — same workload, new workloads, new design -----
    reps = 3 if quick else 10
    warm_walls = []
    edge = Architecture("edge")
    t_before = sess.stats.traces
    for _ in range(reps):
        for name in BUCKET_FAMILY:
            warm_walls.append(timed(sess.simulate, wls[name])[1])
        # a new design point is traced params, not a new program
        warm_walls.append(timed(sess.simulate, wls["lstm"], architecture=edge)[1])
    warm_retraces = sess.stats.traces - t_before
    warm_mean = float(np.mean(warm_walls))
    speedup = cold_s / max(warm_mean, 1e-9)

    # --- optimize: a changed objective mix must reuse the program ---------
    steps = 4 if quick else 16
    sess.optimize(wls["lstm"], objective="mixed",
                  objective_weights=[1.0, 0.0, 0.0, 0.0], steps=steps, report=False)
    d0 = instrument.trace_count("dopt._dopt_step")
    _, opt_warm_s = timed(
        sess.optimize, wls["merge_sort"], objective="mixed",
        objective_weights=[0.0, 0.5, 0.5, 0.0], area_budget=900.0,
        steps=steps, report=False)
    opt_retraces = instrument.trace_count("dopt._dopt_step") - d0

    # --- cold restart: preheat + persistent cache across processes --------
    restart, restart_checks = restart_bench(cold_s, cold_rep.to_json())

    st = sess.stats
    summary = dict(
        bucket_family=BUCKET_FAMILY,
        bucket=list(wls["lstm"].bucket),
        cold_s=round(cold_s, 4),
        cold_traces=cold_traces,
        warm_calls=len(warm_walls),
        warm_mean_s=round(warm_mean, 5),
        warm_p50_s=round(float(np.median(warm_walls)), 5),
        warm_max_s=round(float(np.max(warm_walls)), 5),
        warm_retraces=int(warm_retraces),
        speedup_cold_over_warm=round(speedup, 1),
        optimize_mix_change_retraces=int(opt_retraces),
        optimize_warm_s=round(opt_warm_s, 4),
        cold_restart_s=restart["cold_restart_s"],
        restart=restart,
        session=dict(programs=st.programs, hits=st.hits, misses=st.misses, traces=st.traces),
    )
    emit("api_cache", dict(cold_s=summary["cold_s"], warm_mean_s=summary["warm_mean_s"],
                           speedup=summary["speedup_cold_over_warm"],
                           warm_retraces=summary["warm_retraces"],
                           cold_restart_s=summary["cold_restart_s"],
                           restart_speedup=restart["restart_speedup_vs_cold"]))

    checks = []
    if warm_retraces != 0:
        checks.append(f"warm same-bucket simulate retraced {warm_retraces}x")
    if opt_retraces != 0:
        checks.append(f"changed objective mix retraced the DOpt step {opt_retraces}x")
    if speedup < MIN_SPEEDUP:
        checks.append(f"warm speedup {speedup:.1f} < {MIN_SPEEDUP}")
    checks.extend(restart_checks)
    summary["checks_failed"] = checks

    save_json("api_cache", summary, quick=quick)
    if checks:
        raise SystemExit(f"bench_api acceptance checks failed: {checks}")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
