"""Façade cache probe: the serving guarantee, measured and gated.

``Session`` keys compiled programs by (spec, mapper config, workload shape
bucket, objective signature); the serving pattern — repeated queries over
same-bucket workloads — must replay cached executables.  This bench records
to ``results/bench/api_cache.json`` (``--quick`` -> ``api_cache_quick.json``):

  * **cold** — first ``simulate()`` on a fresh Session (traces + compiles);
  * **warm** — repeated ``simulate()`` over same-bucket workloads (the
    original, a different workload, a different design point), each timed;
  * **optimize warm-over-mixes** — two ``optimize(objective="mixed")``
    calls with different weights/budgets: the second must add zero DOpt-step
    traces (weights are traced arguments, per PR 4).

Acceptance gates (hard-fail, both modes):
  * zero new traces across the whole warm phase;
  * warm mean wall >= MIN_SPEEDUP x lower than cold.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, save_json, timed
from repro.api import Architecture, Session, Workload
from repro.core import instrument

MIN_SPEEDUP = 10.0
# one 32-vertex shape bucket, four distinct workloads
BUCKET_FAMILY = ["lstm", "merge_sort", "dlrm", "gcn"]


def run(quick: bool = False) -> dict:
    sess = Session("base")
    wls = {n: Workload(n) for n in BUCKET_FAMILY}
    assert len({w.bucket for w in wls.values()}) == 1, "probe family must share a bucket"

    # --- cold: first query compiles ---------------------------------------
    _, cold_s = timed(sess.simulate, wls["lstm"])
    cold_traces = sess.stats.traces

    # --- warm: same bucket — same workload, new workloads, new design -----
    reps = 3 if quick else 10
    warm_walls = []
    edge = Architecture("edge")
    t_before = sess.stats.traces
    for _ in range(reps):
        for name in BUCKET_FAMILY:
            warm_walls.append(timed(sess.simulate, wls[name])[1])
        # a new design point is traced params, not a new program
        warm_walls.append(timed(sess.simulate, wls["lstm"], architecture=edge)[1])
    warm_retraces = sess.stats.traces - t_before
    warm_mean = float(np.mean(warm_walls))
    speedup = cold_s / max(warm_mean, 1e-9)

    # --- optimize: a changed objective mix must reuse the program ---------
    steps = 4 if quick else 16
    sess.optimize(wls["lstm"], objective="mixed",
                  objective_weights=[1.0, 0.0, 0.0, 0.0], steps=steps, report=False)
    d0 = instrument.trace_count("dopt._dopt_step")
    _, opt_warm_s = timed(
        sess.optimize, wls["merge_sort"], objective="mixed",
        objective_weights=[0.0, 0.5, 0.5, 0.0], area_budget=900.0,
        steps=steps, report=False)
    opt_retraces = instrument.trace_count("dopt._dopt_step") - d0

    st = sess.stats
    summary = dict(
        bucket_family=BUCKET_FAMILY,
        bucket=list(wls["lstm"].bucket),
        cold_s=round(cold_s, 4),
        cold_traces=cold_traces,
        warm_calls=len(warm_walls),
        warm_mean_s=round(warm_mean, 5),
        warm_p50_s=round(float(np.median(warm_walls)), 5),
        warm_max_s=round(float(np.max(warm_walls)), 5),
        warm_retraces=int(warm_retraces),
        speedup_cold_over_warm=round(speedup, 1),
        optimize_mix_change_retraces=int(opt_retraces),
        optimize_warm_s=round(opt_warm_s, 4),
        session=dict(programs=st.programs, hits=st.hits, misses=st.misses, traces=st.traces),
    )
    emit("api_cache", dict(cold_s=summary["cold_s"], warm_mean_s=summary["warm_mean_s"],
                           speedup=summary["speedup_cold_over_warm"],
                           warm_retraces=summary["warm_retraces"]))

    checks = []
    if warm_retraces != 0:
        checks.append(f"warm same-bucket simulate retraced {warm_retraces}x")
    if opt_retraces != 0:
        checks.append(f"changed objective mix retraced the DOpt step {opt_retraces}x")
    if speedup < MIN_SPEEDUP:
        checks.append(f"warm speedup {speedup:.1f} < {MIN_SPEEDUP}")
    summary["checks_failed"] = checks

    save_json("api_cache", summary, quick=quick)
    if checks:
        raise SystemExit(f"bench_api acceptance checks failed: {checks}")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
