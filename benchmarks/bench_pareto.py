"""Population-scale multi-objective DSE: the latency/energy/area frontier.

Runs through ``Session.frontier`` (the popsim engine underneath is
unchanged).  Three records, one JSON (``results/bench/pareto.json``;
``--quick`` writes ``pareto_quick.json`` per the quick-probe convention):

  * **front quality** — size and hypervolume of the constrained Pareto
    front the façade extracts from a library-seeded population, plus the
    per-winner metrics, budget slack, and ``.dhd`` round-trip check;
  * **engine throughput** — member-epochs/sec of the vmapped
    device-resident population chunk vs *the same trajectories* run as
    sequential ``optimize(objective="mixed")`` calls (identical starts,
    weights, budgets, constant penalty weight — the first member's
    trajectory is asserted equal, so the comparison is work-for-work).
    This comparison deliberately reaches past the façade into the engine
    (tagged ``# engine-oracle`` for the API-surface lint): its whole point
    is to measure the population engine against the raw sequential path;
  * **acceptance gates** — front >= MIN_FRONT mutually non-dominated
    designs from >= 3 ``.dhd`` seeds, every front member within budget and
    round-tripping bit-exactly, engine >= MIN_SPEEDUP x sequential.

The sequential baseline pays, per candidate: Graph.stack of the workload
set, log-space + Adam state init, per-chunk dispatch + host sync, history
conversion — all host work the population engine does once per *population*
(and the vmapped mapper batches the math besides).  That per-call overhead
is not an artifact: it is what multi-start DSE by an optimize() loop over
raw graphs actually costs warm (a Session user amortizes the stacking, but
still pays the rest per call).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.api import PARETO_METRICS, Architecture, Session, Workload
from repro.core.dhdl import parse_arch
from repro.core.dopt import optimize  # engine-oracle: sequential DSE baseline
from repro.core.pareto import dominates
from repro.core.popsim import (  # engine-oracle: work-for-work throughput comparison
    init_population_state,
    population_chunk,
    sample_objective_mixes,
    seed_population,
)

WORKLOADS = ["lstm", "bert_base", "merge_sort"]  # the dopt_throughput stack
MIN_FRONT = 8
# The gate guards the *batching* win: losing the vmapped engine is a >10x
# cliff to ~1x.  Host-side speed of the sequential baseline varies ~2x
# across recording machines (PR 4's machine ran it at 949 member-epochs/s,
# a later idle machine at ~2000 with the engine rate unchanged at ~13k),
# so the floor sits below the worst honest measurement, not at the best.
MIN_SPEEDUP = 5.0


def _seed_budgets(sess: Session, seeds, wl: Workload):
    """Budgets + a run-independent hypervolume box, from the library itself.

    Budgets are the worst-case area/power of the largest seed design —
    every seed starts feasible, growth-hungry objective mixes run into a
    binding ceiling.  The hypervolume sample box is anchored on the seeds'
    (time, energy, area) log metrics — stable across runs as long as the
    library and workload stack are, so the recorded hypervolume is a
    comparable trend metric: lo leaves ~e^3 (20x) improvement headroom per
    axis, ref sits just beyond the worst seed.
    """
    areas, powers, logms = [], [], []
    for nm in seeds:
        rep = sess.simulate(wl, architecture=Architecture(nm))
        areas.append(rep.area_mm2)
        powers.append(max(w.power_w for w in rep.workloads))
        logms.append(
            [
                np.mean([np.log(w.runtime_s) for w in rep.workloads]),
                np.mean([np.log(w.energy_j) for w in rep.workloads]),
                np.log(rep.area_mm2),
            ]
        )
    logms = np.asarray(logms)
    hv_box = (logms.min(axis=0) - 3.0, logms.max(axis=0) + 0.5)
    return max(areas), max(powers), hv_box


def _throughput(wl: Workload, seeds, population, steps, lr, area_b, power_b):
    """Engine vs sequential member-epochs/sec on identical trajectories.

    Work-for-work: both paths run the workload set stacked to its natural
    V_max (not the façade's pow2 bucket), so the engine's advantage is the
    batching, not a padding asymmetry — and the sequential side re-stacks
    per call, which is exactly what an optimize() loop over raw graphs pays.
    """
    gl = list(wl.graphs)  # the sequential caller's raw per-call input
    from repro.api import Graph

    gstack = Graph.stack(gl)
    key = jax.random.PRNGKey(0)
    (tech, arch), spec, _ = seed_population(population, seeds, key)
    weights = sample_objective_mixes(population)
    mixes = (
        weights,
        jnp.full((population,), jnp.float32(area_b)),
        jnp.full((population,), jnp.float32(power_b)),
    )
    pw = jnp.full((steps,), jnp.float32(2.0))  # constant, so optimize() can replay it

    # --- population engine: sustained rate = the chunk dispatch + its host
    # sync.  State init happens once per *population* and is donated, so two
    # states are built outside the clock: one to compile, one to time.
    population_chunk(init_population_state(tech, arch), mixes, gstack, lr, pw, spec=spec)  # compile
    state = init_population_state(tech, arch)
    jax.block_until_ready(jax.tree.leaves(state))
    t0 = time.perf_counter()
    _, metrics = population_chunk(state, mixes, gstack, lr, pw, spec=spec)
    metrics = np.asarray(metrics)  # include the host sync the driver pays
    pop_wall = time.perf_counter() - t0
    pop_eps = population * steps / pop_wall

    # --- sequential baseline: the same trajectories via optimize() --------
    # start points are extracted outside the timed loop: a user doing
    # multi-start DSE holds per-candidate starts already, so only optimize()
    # itself is on the clock
    starts = [
        (jax.tree.map(lambda x: x[i], tech), jax.tree.map(lambda x: x[i], arch))
        for i in range(population)
    ]

    def seq_call(i):
        # raw graph list, not the pre-bucketed stack: the per-call
        # Graph.stack is part of what the sequential path really pays
        return optimize(
            gl,
            tech=starts[i][0],
            arch=starts[i][1],
            spec=spec,
            objective="mixed",
            objective_weights=weights[i],
            area_budget=area_b,
            power_budget=power_b,
            penalty_weight=2.0,
            steps=steps,
            lr=lr,
        )

    res0 = seq_call(0)  # compile warm-up — and the same-trajectory proof:
    np.testing.assert_allclose(
        np.asarray(res0.history["objective"]), metrics[:, 0, 0], rtol=1e-4
    )
    t0 = time.perf_counter()
    for i in range(population):
        seq_call(i)
    seq_wall = time.perf_counter() - t0
    seq_eps = population * steps / seq_wall

    row = dict(
        population=population,
        steps=steps,
        pop_wall_s=round(pop_wall, 3),
        seq_wall_s=round(seq_wall, 3),
        pop_member_epochs_per_s=round(pop_eps, 1),
        seq_member_epochs_per_s=round(seq_eps, 1),
        speedup=round(pop_eps / seq_eps, 1),
    )
    emit("pareto_throughput", row)
    return row


def run(quick: bool = False, population: int | None = None, steps: int | None = None) -> dict:
    seeds = ("base", "edge", "datacenter") if quick else ("base", "edge", "mobile", "datacenter", "hbm_class")
    population = (12 if quick else 32) if population is None else population
    steps = (8 if quick else 24) if steps is None else steps
    lr = 0.1
    sess = Session("base")
    wl = Workload(WORKLOADS)
    area_b, power_b, hv_box = _seed_budgets(sess, seeds, wl)

    thr = _throughput(wl, seeds, population, steps, lr, area_b, power_b)

    t0 = time.perf_counter()
    fr = sess.frontier(
        wl,
        seeds=seeds,
        population=population,
        steps=steps,
        lr=lr,
        area_budget=area_b,
        power_budget=power_b,
        penalty_weight=(0.25, 4.0),
        key=0,
        hv_box=hv_box,
    )
    dse_wall = time.perf_counter() - t0
    res = fr.raw  # the engine's ParetoResult, for the acceptance checks

    # --- acceptance checks: non-domination, budgets, .dhd round-trips -----
    sub = jnp.asarray(res.front_log_metrics)
    mutually_nd = bool(
        res.front.size == 0
        or not np.asarray(dominates(sub[:, None], sub[None, :])).any()
    )
    budget_ok = bool(res.feasible[res.front].all()) if res.front.size else False
    roundtrip_ok = True
    for p in fr.front:
        ca = parse_arch(p.dhd)
        i = p.index
        for got, want in zip(
            jax.tree.leaves((ca.tech, ca.arch)),
            jax.tree.leaves(
                (jax.tree.map(lambda x: x[i], res.tech), jax.tree.map(lambda x: x[i], res.arch))
            ),
        ):
            roundtrip_ok &= bool(np.array_equal(np.asarray(got), np.asarray(want)))

    front_row = dict(
        front_size=len(fr.front),
        hypervolume=round(fr.hypervolume, 4),
        feasible=fr.feasible,
        population=population,
        seeds=len(seeds),
        mutually_non_dominated=mutually_nd,
        budget_ok=budget_ok,
        roundtrip_ok=roundtrip_ok,
        wall_s=round(dse_wall, 1),
    )
    emit("pareto_front", front_row)

    summary = dict(
        workloads=WORKLOADS,
        seeds=list(seeds),
        population=population,
        steps=steps,
        lr=lr,
        area_budget_mm2=round(area_b, 1),
        power_budget_w=round(power_b, 2),
        budget_tol=0.05,
        throughput=thr,
        front=front_row,
        hv_lo=None if not fr.front else [round(float(x), 4) for x in res.hv_lo],
        hv_ref=None if not fr.front else [round(float(x), 4) for x in res.hv_ref],
        winners=[
            dict(
                index=p.index, seed=p.seed,
                weights={m: w for m, w in zip(PARETO_METRICS, p.weights)},
                time_s=p.time_s, energy_j=p.energy_j, area_mm2=p.area_mm2,
                power_w=p.power_w, edp=p.edp, dhd=p.dhd,
            )
            for p in fr.front
        ],
    )

    checks = []
    if front_row["front_size"] < 1:
        checks.append("empty Pareto front")
    if not quick:
        if front_row["front_size"] < MIN_FRONT:
            checks.append(f"front {front_row['front_size']} < {MIN_FRONT}")
        if thr["speedup"] < MIN_SPEEDUP:
            checks.append(f"speedup {thr['speedup']} < {MIN_SPEEDUP}")
    if not mutually_nd:
        checks.append("front not mutually non-dominated")
    if fr.front and not budget_ok:
        checks.append("front member violates budget")
    if not roundtrip_ok:
        checks.append(".dhd round-trip mismatch")
    summary["checks_failed"] = checks

    save_json("pareto", summary, quick=quick)
    if checks:
        raise SystemExit(f"bench_pareto acceptance checks failed: {checks}")
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--population", type=int, default=None)
    ap.add_argument("--steps", type=int, default=None)
    args = ap.parse_args()
    run(quick=args.quick, population=args.population, steps=args.steps)
