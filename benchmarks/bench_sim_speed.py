"""Paper §8.1 / Fig. 4 / Table 1: simulator speed + accuracy.

DSim — served through the Session façade's cached compiled program, the
production query path — vs the reference per-tile cycle-walker (refsim.py,
our stand-in for SCALE-Sim/Timeloop-class tools, same per-tile-stepping
asymptotics).  The refsim/kernel comparisons reach past the façade by
design (tagged ``# engine-oracle`` for the API-surface lint): they ARE the
accuracy/speed oracle the façade path is measured against.  Reported per
workload:

  * accuracy  = 1 - |cycles_dsim - cycles_ref| / cycles_ref  (paper: 80-97%)
  * speedup   = wall_ref / wall_dsim                          (paper: ~1000x)

plus the popsim Pallas kernel evaluating a 512-candidate population, which
is the per-candidate cost DOpt's DSE pays.

Dispatch note: the façade buckets every workload to >= 32 vertices, so the
mapper's auto dispatch always takes the associative formulation — on CPU
that puts a flat ~0.2-0.4 ms fan-out floor under *forward-only* dispatch
of small graphs (the formulation optimizes the DOpt/DSE gradient path,
where it is 5-16x faster; see ROADMAP "Mapper: associative-scan
formulation").  Forward-heavy deployments can force
``Session(mcfg=MapperCfg(scan_impl="ref"))``; this bench records the
serving *default*, with the padded size in each row's ``bucket`` column.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, save_json
from repro.api import ArchParams, Session, TechParams, Workload
from repro.core.dgen import specialize  # engine-oracle: refsim consumes a raw CHW
from repro.core.refsim import reference_simulate  # engine-oracle: accuracy baseline
from repro.kernels import pack_chw, pack_graph, popsim  # engine-oracle: kernel microbench
from repro.workloads import get_workload, lm_cell

CLASSIC = ["resnet50", "vgg16", "lstm", "dlrm", "bert_base", "bert_large",
           "gcn", "graphsage", "stencil2d", "merge_sort", "bfs_graph"]
LM = [("qwen2.5-32b", "prefill_32k"), ("granite-3-8b", "train_4k"),
      ("kimi-k2-1t-a32b", "decode_32k"), ("falcon-mamba-7b", "long_500k"),
      ("zamba2-1.2b", "train_4k")]


def run(quick: bool = False) -> dict:
    sess = Session("base")  # bit-identical to the dataclass defaults
    chw = specialize(TechParams.default(), ArchParams.default())
    rows = []
    names = CLASSIC[:4] if quick else CLASSIC
    lms = LM[:2] if quick else LM
    graphs = [(n, get_workload(n)) for n in names]
    graphs += [(f"{a}:{s}", lm_cell(a, s)) for a, s in lms]

    for name, g in graphs:
        wl = Workload(g, labels=(name,))
        # compile timed separately; steady-state iterations sync with
        # block_until_ready (no scalar device->host transfer in the loop).
        # sess.perf is the cached-program serving path: same-bucket repeats
        # dispatch the compiled executable directly.
        t0 = time.perf_counter()
        out = jax.block_until_ready(sess.perf(wl).cycles)
        t_compile = time.perf_counter() - t0
        cyc = float(out[0])
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(sess.perf(wl).cycles)
        t_dsim = (time.perf_counter() - t0) / 5

        t0 = time.perf_counter()
        ref = reference_simulate(chw, g)
        t_ref = time.perf_counter() - t0

        acc = 1.0 - abs(cyc - ref["cycles"]) / max(ref["cycles"], 1.0)
        rows.append(dict(workload=name, vertices=g.n_vertices,
                         bucket=wl.bucket[1],
                         cycles_dsim=cyc, cycles_ref=ref["cycles"],
                         accuracy=round(acc, 4),
                         t_dsim_ms=round(t_dsim * 1e3, 3),
                         t_compile_ms=round(t_compile * 1e3, 3),
                         t_ref_ms=round(t_ref * 1e3, 3),
                         speedup=round(t_ref / max(t_dsim, 1e-9), 1)))
        emit("sim_speed", rows[-1])

    # population evaluation (the DSE inner loop): batched Pallas kernel
    P = 128 if quick else 512
    scales = jnp.linspace(0.5, 2.0, P)
    chws = jax.vmap(
        lambda s: specialize(
            dataclasses.replace(TechParams.default(),
                                cell_read_latency=TechParams.default().cell_read_latency * s),
            ArchParams.default())
    )(scales)
    g = get_workload("bert_base")
    gp, cp = pack_graph(g), pack_chw(chws)
    out = popsim(gp, cp)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = popsim(gp, cp)
    jax.block_until_ready(out)
    t_pop = time.perf_counter() - t0
    per_candidate_us = t_pop / P * 1e6
    emit("sim_speed", dict(workload=f"popsim_{P}cand", per_candidate_us=round(per_candidate_us, 1)))

    accs = [r["accuracy"] for r in rows]
    sps = [r["speedup"] for r in rows]
    summary = dict(rows=rows, accuracy_min=min(accs), accuracy_max=max(accs),
                   accuracy_mean=float(np.mean(accs)),
                   speedup_geomean=float(np.exp(np.mean(np.log(np.maximum(sps, 1e-9))))),
                   popsim_per_candidate_us=per_candidate_us)
    emit("sim_speed", dict(summary="1", acc_range=f"{min(accs):.2f}..{max(accs):.2f}",
                           speedup_geomean=round(summary["speedup_geomean"], 1)))
    save_json("sim_speed", summary, quick=quick)
    return summary


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
