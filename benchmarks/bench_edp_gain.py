"""Abstract claim: 'architectures and circuits 5x better than previously
published works [Scale-Sim; Interstellar]'.

Baselines = fixed published-style design points evaluated by DSim:
  * scale-sim-like: 32x32 systolic array, 256KB double-buffered SRAM, 1 GHz
  * interstellar-like (Eyeriss-class): 16x16 PEs, 108KB buffer
  * tpu-v1-like: 256x256 MACs, 24MB unified buffer

DOpt (joint arch+tech, area-constrained to the baseline's area) must beat
each baseline's EDP by >= the paper's 5x on the shared workload set."""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.core import ArchParams, TechParams, optimize, simulate
from repro.workloads import get_workload

BASELINES = {
    "scale-sim-32x32": dict(sys_arr_x=32.0, sys_arr_y=32.0, sys_arr_n=1.0,
                            capacity=[64 * 2**10, 256 * 2**10, 8 * 2**30],
                            frequency=1.0e9),
    "eyeriss-16x16": dict(sys_arr_x=16.0, sys_arr_y=16.0, sys_arr_n=1.0,
                          capacity=[32 * 2**10, 108 * 2**10, 4 * 2**30],
                          frequency=0.2e9),
    "tpu-v1-256x256": dict(sys_arr_x=256.0, sys_arr_y=256.0, sys_arr_n=1.0,
                           capacity=[4 * 2**20, 24 * 2**20, 16 * 2**30],
                           frequency=0.7e9),
}
WORKLOADS = ("resnet50", "bert_base", "lstm")


def _arch_from(d: dict) -> ArchParams:
    base = ArchParams.default()
    kw = {k: (jnp.asarray(v, jnp.float32) if isinstance(v, list) else jnp.float32(v))
          for k, v in d.items()}
    return dataclasses.replace(base, **kw)


def run(quick: bool = False) -> dict:
    tech = TechParams.default()
    out = {}
    workloads = WORKLOADS[:2] if quick else WORKLOADS
    graphs = [get_workload(w) for w in workloads]
    n = len(graphs)
    for name, spec in BASELINES.items():
        arch0 = _arch_from(spec)
        base_edp = 1.0
        for g in graphs:
            base_edp *= float(simulate(tech, arch0, g).edp)
        base_area = float(simulate(tech, arch0, graphs[0]).area)

        def geo_edp(t, a):
            e = 1.0
            for g in graphs:
                e *= float(simulate(t, a, g).edp)
            return e

        # (a) SAME technology (40nm reference), architecture-only — the
        # apples-to-apples "5x better architectures" claim
        res_a = optimize(graphs, arch=arch0, opt_over="arch", objective="edp",
                         steps=15 if quick else 60, lr=0.1, area_constraint=base_area)
        gain_arch = (base_edp / max(geo_edp(TechParams.default(), res_a.arch), 1e-300)) ** (1 / n)
        # (b) joint arch+technology — the "100x/1000x with technology
        # targets" headroom claim
        res_b = optimize(graphs, arch=arch0, opt_over="both", objective="edp",
                         steps=15 if quick else 60, lr=0.1, area_constraint=base_area)
        gain_joint = (base_edp / max(geo_edp(res_b.tech, res_b.arch), 1e-300)) ** (1 / n)

        row = dict(baseline=name,
                   edp_gain_same_tech=round(gain_arch, 1),
                   edp_gain_with_tech_targets=round(gain_joint, 1),
                   base_area_mm2=round(base_area, 1))
        out[name] = row
        emit("edp_gain", row)
    save_json("edp_gain", out, quick=quick)
    return out


if __name__ == "__main__":
    run()
