"""Abstract claim: 'architectures and circuits 5x better than previously
published works [Scale-Sim; Interstellar]'.

Baselines = fixed published-style design points evaluated by DSim (through
the Session façade):
  * scale-sim-like: 32x32 systolic array, 256KB double-buffered SRAM, 1 GHz
  * interstellar-like (Eyeriss-class): 16x16 PEs, 108KB buffer
  * tpu-v1-like: 256x256 MACs, 24MB unified buffer

DOpt (joint arch+tech, area-constrained to the baseline's area) must beat
each baseline's EDP by >= the paper's 5x on the shared workload set."""
from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

from benchmarks.common import emit, save_json
from repro.api import ArchParams, Architecture, Session, Workload

BASELINES = {
    "scale-sim-32x32": dict(sys_arr_x=32.0, sys_arr_y=32.0, sys_arr_n=1.0,
                            capacity=[64 * 2**10, 256 * 2**10, 8 * 2**30],
                            frequency=1.0e9),
    "eyeriss-16x16": dict(sys_arr_x=16.0, sys_arr_y=16.0, sys_arr_n=1.0,
                          capacity=[32 * 2**10, 108 * 2**10, 4 * 2**30],
                          frequency=0.2e9),
    "tpu-v1-256x256": dict(sys_arr_x=256.0, sys_arr_y=256.0, sys_arr_n=1.0,
                           capacity=[4 * 2**20, 24 * 2**20, 16 * 2**30],
                           frequency=0.7e9),
}
WORKLOADS = ("resnet50", "bert_base", "lstm")


def _arch_from(name: str, d: dict) -> Architecture:
    base = ArchParams.default()
    kw = {k: (jnp.asarray(v, jnp.float32) if isinstance(v, list) else jnp.float32(v))
          for k, v in d.items()}
    return Architecture(arch=dataclasses.replace(base, **kw), name=name)


def run(quick: bool = False) -> dict:
    sess = Session("base")
    out = {}
    workloads = WORKLOADS[:2] if quick else WORKLOADS
    wl = Workload(list(workloads))
    n = wl.n_workloads

    def geo_edp(architecture: Architecture) -> float:
        rep = sess.simulate(wl, architecture=architecture)
        return math.prod(w.edp for w in rep.workloads)

    for name, point in BASELINES.items():
        arch0 = _arch_from(name, point)
        rep0 = sess.simulate(wl, architecture=arch0)
        base_edp = math.prod(w.edp for w in rep0.workloads)
        base_area = rep0.area_mm2

        # (a) SAME technology (40nm reference), architecture-only — the
        # apples-to-apples "5x better architectures" claim
        res_a = sess.optimize(wl, architecture=arch0, opt_over="arch", objective="edp",
                              steps=15 if quick else 60, lr=0.1,
                              area_constraint=base_area, report=False)
        gain_arch = (base_edp / max(geo_edp(Architecture(res_a.to_dhd())), 1e-300)) ** (1 / n)
        # (b) joint arch+technology — the "100x/1000x with technology
        # targets" headroom claim
        res_b = sess.optimize(wl, architecture=arch0, opt_over="both", objective="edp",
                              steps=15 if quick else 60, lr=0.1,
                              area_constraint=base_area, report=False)
        gain_joint = (base_edp / max(geo_edp(Architecture(res_b.to_dhd())), 1e-300)) ** (1 / n)

        row = dict(baseline=name,
                   edp_gain_same_tech=round(gain_arch, 1),
                   edp_gain_with_tech_targets=round(gain_joint, 1),
                   base_area_mm2=round(base_area, 1))
        out[name] = row
        emit("edp_gain", row)
    save_json("edp_gain", out, quick=quick)
    return out


if __name__ == "__main__":
    run()
