"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import json
import os
import time
from contextlib import contextmanager

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def emit(table: str, row: dict):
    """One CSV-ish line per result: table,key=value,..."""
    parts = [f"{k}={v}" for k, v in row.items()]
    print(f"[bench:{table}] " + " ".join(parts), flush=True)


def save_json(name: str, obj, quick: bool = False):
    """Persist one benchmark's results.

    Full runs own the canonical ``results/bench/<name>.json`` files that get
    committed; ``--quick`` probes (CI trajectory checks, local smoke) write
    ``<name>_quick.json`` instead so they can never clobber a recorded full
    run.  Every bench must thread its ``quick`` flag through here.
    """
    os.makedirs(os.path.join(RESULTS_DIR, "bench"), exist_ok=True)
    suffix = "_quick" if quick else ""
    path = os.path.join(RESULTS_DIR, "bench", name + suffix + ".json")
    with open(path, "w") as f:
        json.dump(obj, f, indent=1, default=float)
    return path


@contextmanager
def timer():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0


def timed(fn, *args, **kw):
    """``(result, wall_seconds)`` of one call — the cold/warm timing idiom
    the façade benches repeat."""
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0
