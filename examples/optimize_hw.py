"""DRAGON applied to the assigned LM fleet, through the Session façade:
derive technology targets and an accelerator design for serving
qwen2.5-32b, compare architectures' hardware pressure (which arch wants
which technology), and map the constrained latency/energy/area frontier
for the serving cell.

  PYTHONPATH=src python examples/optimize_hw.py [--skip-pareto]
"""
import sys

sys.path.insert(0, "src")

from repro import Session, Workload
from repro.workloads import lm_cell


def pareto_frontier(g_decode, population: int = 12, steps: int = 10):
    """Population-scale multi-objective DSE: what does the latency/energy/
    area trade space of decode-serving look like, and which designs win
    under the edge-class budget?"""
    res = Session().frontier(
        Workload(g_decode), seeds=("base", "edge", "datacenter"),
        population=population, steps=steps, lr=0.1,
        area_budget=700.0, power_budget=150.0, key=0,
    ).raw
    print(f"\nPareto frontier of decode serving ({population} members, "
          f"{steps} epochs, area<=700mm^2, power<=150W): "
          f"{res.front.size} designs, hypervolume {res.hypervolume:.1f}")
    for w in res.winners:
        print(f"   seed={w['seed']:10s} latency {w['time_s']*1e3:7.2f} ms  "
              f"energy {w['energy_j']:7.3f} J  area {w['area_mm2']:7.1f} mm^2  "
              f"power {w['power_w']:6.1f} W")
    return res


def main():
    sess = Session("base")

    # 1. what does DECODE-serving qwen2.5-32b want from hardware? -----------
    g_decode = Workload(lm_cell("qwen2.5-32b", "decode_32k"), labels=("qwen-decode",))
    res = sess.optimize(g_decode, objective="time", opt_over="tech", steps=30, lr=0.08)
    print("qwen2.5-32b decode — top technology levers (objective: time):")
    for a in res.importance[:5]:
        print(f"   {a.parameter:42s} |elasticity| {abs(a.elasticity):.3f}")

    # 2. derive an accelerator design for the same cell ----------------------
    res2 = sess.optimize(g_decode, objective="edp", opt_over="arch", steps=40, lr=0.1)
    from repro import Architecture

    a = Architecture(res2.to_dhd()).arch  # the optimized design, via .dhd text
    print(f"\nderived accelerator: systolic {float(a.sys_arr_x):.0f}x"
          f"{float(a.sys_arr_y):.0f}x{float(a.sys_arr_n):.0f}, "
          f"gbuf {float(a.capacity[1])/2**20:.0f} MB, "
          f"{float(a.frequency)/1e9:.2f} GHz "
          f"(EDP {res2.improvement:.0f}x better)")

    # 3. compare hardware pressure across architecture families --------------
    #    (explain = the same elasticities, served without a descent)
    print("\nper-family #1 technology lever (train_4k):")
    for arch in ("granite-3-8b", "kimi-k2-1t-a32b", "falcon-mamba-7b"):
        rep = sess.explain(Workload(lm_cell(arch, "train_4k")), objective="time")
        top = next(at for at in rep.attribution if at.parameter.startswith("tech."))
        print(f"   {arch:24s} -> {top.parameter.removeprefix('tech.')}")

    # 4. paper Fig. 3: technology targets for 10x EDP on the decode cell -----
    tt = sess.tech_targets(g_decode, goal_factor=10.0, steps=80, lr=0.12)
    print(f"\n10x-EDP technology targets derived in {tt['epochs']} epochs "
          f"(achieved {tt['achieved_factor']:.1f}x):")
    moved = sorted(tt["targets"].items(), key=lambda kv: -abs(kv[1]["factor"] - 1))
    for name, t in moved[:5]:
        print(f"   {name:42s} improve {t['factor']:.1f}x")

    # 5. the budget-constrained latency/energy/area frontier -----------------
    if "--skip-pareto" not in sys.argv:
        pareto_frontier(lm_cell("qwen2.5-32b", "decode_32k"))


if __name__ == "__main__":
    main()
