"""Serving demo: continuous batching with mixed prompt lengths, temperatures
and arrival times on a reduced qwen2.5 config (same engine the production
launcher uses; slots/caches/sampling identical).

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Engine, Request


def main():
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=4, max_len=128)

    rng = np.random.default_rng(7)
    t0 = time.time()
    for i in range(10):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
            max_tokens=int(rng.integers(4, 12)),
            temperature=float(rng.choice([0.0, 0.7, 1.0])),
            seed=i,
        ))
    done = eng.run()
    wall = time.time() - t0

    toks = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {wall:.1f}s "
          f"({toks / wall:.1f} tok/s on CPU)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] temp={r.temperature} "
              f"-> {[int(np.asarray(t)) for t in r.generated]}")


if __name__ == "__main__":
    main()
