"""Serving demo, both engines:

1. continuous token batching with mixed prompt lengths, temperatures and
   arrival times on a reduced qwen2.5 config (same engine the production
   launcher uses; slots/caches/sampling identical);
2. DRAGON design queries as a service: a DesignService answers a mixed
   stream of simulate/explain/optimize questions against one compiled
   model — after the first query per shape bucket, everything is warm
   (the Session compiled-program cache; see docs/api.md).

  PYTHONPATH=src python examples/serve_demo.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import DesignQuery, DesignService, Engine, Request


def token_demo():
    cfg = get_config("qwen2.5-32b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, slots=4, max_len=128)

    rng = np.random.default_rng(7)
    t0 = time.time()
    for i in range(10):
        plen = int(rng.integers(4, 24))
        eng.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, (plen,)).astype(np.int32),
            max_tokens=int(rng.integers(4, 12)),
            temperature=float(rng.choice([0.0, 0.7, 1.0])),
            seed=i,
        ))
    done = eng.run()
    wall = time.time() - t0

    toks = sum(len(r.generated) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {wall:.1f}s "
          f"({toks / wall:.1f} tok/s on CPU)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: prompt[{len(r.prompt)}] temp={r.temperature} "
              f"-> {[int(np.asarray(t)) for t in r.generated]}")


def design_demo():
    svc = DesignService("base")
    queries = [
        DesignQuery(0, "simulate", "lstm"),
        DesignQuery(1, "simulate", "merge_sort"),              # same bucket: warm
        DesignQuery(2, "simulate", "dlrm", architecture="edge"),  # new design: warm
        DesignQuery(3, "explain", "lstm", objective="edp"),
        DesignQuery(4, "explain", "dlrm", objective="edp"),    # warm
        DesignQuery(5, "optimize", "lstm", objective="edp",
                    params=dict(steps=8, lr=0.05)),
        DesignQuery(6, "optimize", "merge_sort", objective="edp",
                    params=dict(steps=8, lr=0.05)),            # warm
    ]
    replies = svc.serve(queries)
    print("\ndesign-query service (one compiled model, many questions):")
    for r in replies:
        print(f"  q{r.qid} {r.kind:9s} {'cold' if r.compiled else 'warm':4s} "
              f"{r.wall_s * 1e3:8.1f} ms")
    st = svc.stats
    warm = [r.wall_s for r in replies if not r.compiled and r.kind == "simulate"]
    cold = [r.wall_s for r in replies if r.compiled and r.kind == "simulate"]
    if warm and cold:
        print(f"  simulate cold->warm: {min(cold) / max(min(warm), 1e-9):.0f}x faster")
    print(f"  cache: {st.programs} programs, {st.hits} hits, {st.traces} traces")


def main():
    token_demo()
    design_demo()


if __name__ == "__main__":
    main()
