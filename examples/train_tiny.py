"""End-to-end training driver: a ~100M-param dense LM trained for a few
hundred steps on CPU with the full production stack — sharded data pipeline,
AdamW (+schedule), remat, checkpointing, straggler monitor — exactly the
code path launch/train.py uses on a pod.

  PYTHONPATH=src python examples/train_tiny.py [--steps 300]
"""
import argparse
import dataclasses
import sys
import tempfile

sys.path.insert(0, "src")

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import make_local_mesh
from repro.models.model import build_model
from repro.optim import AdamWConfig, warmup_cosine
from repro.train import TrainConfig, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    # ~100M params: 8L x 768d x 12H, 32k vocab (GPT-2-small-class)
    cfg = ModelConfig(
        name="tiny-100m", family="dense", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=3072, vocab_size=32768, mlp_type="swiglu",
        remat="none", fsdp=False, use_flash=False, dtype="float32",
    )
    model = build_model(cfg)
    print(f"model: {model.param_count()/1e6:.1f}M params")

    shape = ShapeConfig("train_tiny", args.seq, args.batch, "train")
    mesh = make_local_mesh()
    with tempfile.TemporaryDirectory() as ckpt_dir, mesh:
        trainer = Trainer(
            model, shape,
            AdamWConfig(lr=6e-4, schedule=warmup_cosine(50, args.steps)),
            TrainConfig(microbatches=1),
            TrainerConfig(steps=args.steps, ckpt_every=100, ckpt_dir=ckpt_dir,
                          log_every=20),
            mesh=mesh,
        )
        out = trainer.run()
    first, last = out["losses"][0], out["losses"][-1]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall']:.0f}s); structure of the synthetic stream was learned"
          if last < first else "loss did not improve — investigate!")
    assert last < first - 0.5, (first, last)


if __name__ == "__main__":
    main()
