"""Quickstart: DRAGON in 60 seconds.

Simulate a BERT-class workload on a TPU-v1-flavoured accelerator, look at
where the time/energy goes, then let DOpt improve the design's EDP and
derive which *technology* parameters matter most.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.core import ArchParams, TechParams, load_arch, optimize, parse_arch, simulate
from repro.workloads import get_workload


def main():
    # 1. a workload is a dataflow graph ------------------------------------
    g = get_workload("bert_base")
    print(f"workload: bert_base — {g.n_vertices} vertices, "
          f"{float(g.total_flops)/1e9:.1f} GFLOPs")

    # 2. DSim: simulate it on the default accelerator ----------------------
    tech, arch = TechParams.default(), ArchParams.default()
    perf = simulate(tech, arch, g)
    print(f"baseline : runtime {float(perf.runtime)*1e3:8.2f} ms   "
          f"energy {float(perf.energy)*1e3:8.2f} mJ   "
          f"area {float(perf.area):6.1f} mm^2   EDP {float(perf.edp):.3e}")

    # 3. architectures are text: the .dhd description language --------------
    #    (library: base / edge / mobile / datacenter / rram_cim / hbm_class /
    #     wafer_scale — see src/repro/configs/arch/ and docs/dhdl.md)
    edge = load_arch("edge")
    p_edge = simulate(edge.tech, edge.arch, g, edge.spec)
    print(f"edge.dhd : runtime {float(p_edge.runtime)*1e3:8.2f} ms   "
          f"energy {float(p_edge.energy)*1e3:8.2f} mJ   "
          f"area {float(p_edge.area):6.1f} mm^2")
    mine = parse_arch("""
        arch my_edge inherits edge {          # compose by inheritance
          memory globalBuf { capacity *= 4 }  # ...and multiplicative tweaks
          compute systolicArray { x = 128  y = 128 }
        }""")
    p_mine = simulate(mine.tech, mine.arch, g, mine.spec)
    print(f"my_edge  : runtime {float(p_mine.runtime)*1e3:8.2f} ms   "
          f"(4x buffer + bigger array, straight from text)")

    # 4. the WHOLE simulator is differentiable ------------------------------
    grads = jax.grad(lambda t: simulate(t, arch, g).edp)(tech)
    print(f"d EDP / d DRAM-cell-latency = {float(grads.cell_read_latency[2]):.3e}"
          "  <- gradients through the mapping itself")

    # 5. DOpt: gradient-descend the design (arch + technology jointly) ------
    res = optimize(g, objective="edp", steps=40, lr=0.1)
    final = simulate(res.tech, res.arch, g)
    print(f"optimized: runtime {float(final.runtime)*1e3:8.2f} ms   "
          f"energy {float(final.energy)*1e3:8.2f} mJ   "
          f"EDP {float(final.edp):.3e}  "
          f"({float(perf.edp)/float(final.edp):.0f}x better)")
    print("top technology levers:",
          " > ".join(n for n, _ in res.importance[:4]))


if __name__ == "__main__":
    main()
