"""Quickstart: DRAGON in 60 seconds, through the front door.

The whole suite is three types::

    from repro import Session, Architecture, Workload

    sess = Session(Architecture("edge"))          # a design point
    rep = sess.simulate(Workload("bert_base"))    # DSim -> SimReport
    rep = sess.explain("bert_base")               # + gradient attribution
    opt = sess.optimize("bert_base", steps=40)    # DOpt -> OptResult
    front = sess.frontier(["lstm", "bert_base"])  # popsim -> FrontierResult

A Session caches compiled programs by (spec, mapper config, workload shape
bucket, objective) — repeated queries, the serving pattern, never retrace
and never recompile (see sess.stats).

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro import Architecture, Session, Workload


def main():
    # 1. a Session serves queries against one architecture -----------------
    sess = Session(Architecture("base"))  # .dhd library name | text | pytrees
    wl = Workload("bert_base")
    print(f"workload: {wl}")

    # 2. DSim: simulate it — the report explains where time/energy went ----
    rep = sess.simulate(wl)
    w = rep.workloads[0]
    print(f"baseline : runtime {w.runtime_s * 1e3:8.2f} ms   "
          f"energy {w.energy_j * 1e3:8.2f} mJ   "
          f"area {rep.area_mm2:6.1f} mm^2   EDP {w.edp:.3e}")
    hot = w.top_vertices(1)[0]
    print(f"hottest vertex: {hot.name} ({hot.time_share:.0%} of runtime)")

    # 3. architectures are text (.dhd); one constructor for every spelling -
    edge = Architecture("edge")
    p_edge = sess.simulate(wl, architecture=edge)
    print(f"edge.dhd : runtime {p_edge.runtime_s * 1e3:8.2f} ms   "
          f"energy {p_edge.energy_j * 1e3:8.2f} mJ   "
          f"area {p_edge.area_mm2:6.1f} mm^2")
    mine = Architecture("""
        arch my_edge inherits edge {          # compose by inheritance
          memory globalBuf { capacity *= 4 }  # ...and multiplicative tweaks
          compute systolicArray { x = 128  y = 128 }
        }""")
    p_mine = sess.simulate(wl, architecture=mine)
    print(f"my_edge  : runtime {p_mine.runtime_s * 1e3:8.2f} ms   "
          f"(4x buffer + bigger array, straight from text)")

    # 4. the WHOLE simulator is differentiable — explain() serves the
    #    gradients as ranked bottleneck attribution --------------------------
    exp = sess.explain(wl, objective="edp")
    print("EDP bottlenecks (d log EDP / d log param):")
    for a in exp.bottlenecks(3):
        print(f"   {a.action:8s} {a.parameter:40s} |e| {abs(a.elasticity):.3f}")

    # 5. DOpt: gradient-descend the design (arch + technology jointly) ------
    opt = sess.optimize(wl, objective="edp", steps=40, lr=0.1)
    o = opt.optimized.workloads[0]
    print(f"optimized: runtime {o.runtime_s * 1e3:8.2f} ms   "
          f"energy {o.energy_j * 1e3:8.2f} mJ   "
          f"EDP {o.edp:.3e}  ({opt.improvement:.0f}x better)")
    print("top technology levers:",
          " > ".join(a.parameter for a in opt.importance[:4]))
    # the optimized design round-trips through .dhd text
    print(f"optimized design serializes to {len(opt.to_dhd().splitlines())} "
          f"lines of .dhd")

    # 6. the serving pattern: warm queries never retrace --------------------
    t0 = sess.stats.traces
    sess.simulate(wl, architecture=mine)  # warm: same bucket, new design point
    st = sess.stats
    print(f"session cache: {st.programs} programs, {st.hits} hits, "
          f"{st.traces} traces ({st.traces - t0} new for the warm query)")


if __name__ == "__main__":
    main()
