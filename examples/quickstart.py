"""Quickstart: DRAGON in 60 seconds.

Simulate a BERT-class workload on a TPU-v1-flavoured accelerator, look at
where the time/energy goes, then let DOpt improve the design's EDP and
derive which *technology* parameters matter most.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro.core import ArchParams, TechParams, optimize, simulate
from repro.workloads import get_workload


def main():
    # 1. a workload is a dataflow graph ------------------------------------
    g = get_workload("bert_base")
    print(f"workload: bert_base — {g.n_vertices} vertices, "
          f"{float(g.total_flops)/1e9:.1f} GFLOPs")

    # 2. DSim: simulate it on the default accelerator ----------------------
    tech, arch = TechParams.default(), ArchParams.default()
    perf = simulate(tech, arch, g)
    print(f"baseline : runtime {float(perf.runtime)*1e3:8.2f} ms   "
          f"energy {float(perf.energy)*1e3:8.2f} mJ   "
          f"area {float(perf.area):6.1f} mm^2   EDP {float(perf.edp):.3e}")

    # 3. the WHOLE simulator is differentiable ------------------------------
    grads = jax.grad(lambda t: simulate(t, arch, g).edp)(tech)
    print(f"d EDP / d DRAM-cell-latency = {float(grads.cell_read_latency[2]):.3e}"
          "  <- gradients through the mapping itself")

    # 4. DOpt: gradient-descend the design (arch + technology jointly) ------
    res = optimize(g, objective="edp", steps=40, lr=0.1)
    final = simulate(res.tech, res.arch, g)
    print(f"optimized: runtime {float(final.runtime)*1e3:8.2f} ms   "
          f"energy {float(final.energy)*1e3:8.2f} mJ   "
          f"EDP {float(final.edp):.3e}  "
          f"({float(perf.edp)/float(final.edp):.0f}x better)")
    print("top technology levers:",
          " > ".join(n for n, _ in res.importance[:4]))


if __name__ == "__main__":
    main()
